"""Batched ingestion tests (ISSUE 3): `insert_batch` vs sequential
`insert` graph-quality parity, batched-distance parity vs the frozen seed
oracle, the kernel dispatch seam, the per-group quantization hoist, and
`save_models` crash injection (all-or-nothing across the batch)."""

import json
import os
from collections import Counter

import numpy as np
import pytest

from repro.core import StorageEngine
from repro.core import catalog as catmod
from repro.core import hnsw as hnswmod
from repro.core.catalog import InjectedCrash
from repro.core.hnsw import HNSWIndex
from repro.core.hnsw_ref import quantized_l2_batch_dense
from repro.core.loader import materialize_many
from repro.core.quantize import (
    dequantize_linear_batch,
    quantize_linear,
    quantize_linear_batch,
)

RNG = np.random.default_rng(33)
TOL = 2.0 ** -24 * 1.001 + 1e-9  # default tolerance + fp slack


@pytest.fixture(autouse=True)
def _clear_failpoints():
    catmod.FAILPOINTS.clear()
    yield
    catmod.FAILPOINTS.clear()


# ------------------------------------------------- quantization hoist parity
def test_quantize_linear_batch_exact_parity():
    """The per-group hoisted sweep must be bit-exact with the per-tensor
    path — codes, scales, zero-points and mids all equal."""
    rng = np.random.default_rng(1)
    x = rng.normal(0, rng.uniform(1e-3, 5.0), (24, 133))
    x[5] = 0.25          # constant row
    x[9] = -1e-12        # tiny constant row
    x[11] *= 1e6         # huge range
    codes, scales, zps, mids = quantize_linear_batch(x)
    for i in range(x.shape[0]):
        qi, meta = quantize_linear(x[i])
        assert np.array_equal(codes[i], qi), f"row {i} codes diverge"
        assert scales[i] == meta.scale
        assert zps[i] == meta.zero_point
        assert mids[i] == meta.mid
    # and the batched dequantizer inverts per-row like the scalar one
    deq = dequantize_linear_batch(codes, scales, zps, mids)
    assert deq.shape == x.shape


try:
    from hypothesis import given, strategies as st  # noqa: E402
except ImportError:
    given = None

if given is not None:
    @given(
        scale=st.floats(1e-6, 1e4),
        loc=st.floats(-10.0, 10.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_quantize_linear_batch_parity_property(scale, loc, seed):
        """Property form of the hoist parity (examples scale with the
        hypothesis profile — the CI profile runs many more)."""
        rng = np.random.default_rng(seed)
        x = rng.normal(loc, scale, (4, 65))
        codes, scales, zps, mids = quantize_linear_batch(x)
        for i in range(4):
            qi, meta = quantize_linear(x[i])
            assert np.array_equal(codes[i], qi)
            assert (scales[i], zps[i], mids[i]) == (
                meta.scale, meta.zero_point, meta.mid
            )


# ------------------------------------------------------ batched distances
def test_multi_query_batch_distances_match_dense_oracle():
    rng = np.random.default_rng(2)
    dim = 96
    idx = HNSWIndex(dim, seed=0)
    for row in rng.normal(0, 1, (50, dim)):
        idx.insert(row)
    idx.insert(np.full(dim, 0.5))  # constant vertex: scale == 0 path
    n = len(idx)
    queries = rng.normal(0, 1, (9, dim))
    got = idx.batch_distances(queries)
    assert got.shape == (9, n)
    for b in range(9):
        want = quantized_l2_batch_dense(
            queries[b], idx._codes[:n], idx._scales[:n], idx._zps[:n],
            idx._mids[:n],
        )
        np.testing.assert_allclose(got[b], want, rtol=1e-6)
    # 1-D query keeps the legacy (N,) contract
    one = idx.batch_distances(queries[0])
    assert one.shape == (n,)
    # (1-row gemv and B-row gemm take different BLAS paths; both sit well
    # inside the documented 1e-6 decomposed-form budget)
    np.testing.assert_allclose(one, got[0], rtol=1e-6)


def test_kernel_dispatch_seam_is_consulted(monkeypatch):
    """Large blocks must be offered to the kernel hook; small ones and
    hook-declined blocks use the numpy fallback with identical results."""
    rng = np.random.default_rng(3)
    dim = 64
    idx = HNSWIndex(dim, seed=0)
    for row in rng.normal(0, 1, (40, dim)):
        idx.insert(row)
    q = rng.normal(0, 1, (3, dim))
    baseline = idx.batch_distances(q)

    calls = []

    def spy(queries, codes, scales, zps, mids):
        calls.append(codes.shape)
        return None  # decline → numpy fallback

    monkeypatch.setattr(hnswmod, "_offload_distances", spy)
    # Below the floor: the seam must NOT be consulted.
    np.testing.assert_array_equal(idx.batch_distances(q), baseline)
    assert calls == []
    # Floor lowered: consulted once per block, fallback result unchanged.
    monkeypatch.setattr(hnswmod, "KERNEL_DISPATCH_MIN_ELEMS", 1)
    np.testing.assert_array_equal(idx.batch_distances(q), baseline)
    assert calls == [(40, dim)]

    # A hook that answers wins (distances come back clamped float64).
    def fake(queries, codes, scales, zps, mids):
        return np.full((queries.shape[0], codes.shape[0]), 7.0)

    monkeypatch.setattr(hnswmod, "_offload_distances", fake)
    assert float(idx.batch_distances(q)[0, 0]) == 7.0


def test_kernel_path_parity_vs_seed_oracle():
    """ops.quantized_l2_auto(force='kernel') — the TPU route, executed in
    interpret mode here — must match the frozen seed oracle."""
    pytest.importorskip("jax")
    from repro.kernels import ops

    rng = np.random.default_rng(4)
    n, d = 64, 256
    codes = rng.integers(0, 256, (n, d)).astype(np.uint8)
    scales = rng.uniform(1e-3, 2e-2, n)
    scales[3] = 0.0
    zps = rng.integers(0, 256, n).astype(np.int64)
    mids = rng.normal(0, 0.5, n)
    queries = rng.normal(0, 1, (2, d))

    assert ops.quantized_l2_auto(queries, codes, scales, zps, mids) is None
    assert (
        ops.quantized_l2_auto(
            queries, codes, scales, zps, mids, force="numpy"
        )
        is None
    )
    got = ops.quantized_l2_auto(queries, codes, scales, zps, mids,
                                force="kernel")
    assert got.shape == (2, n)
    for b in range(2):
        want = quantized_l2_batch_dense(queries[b], codes, scales, zps, mids)
        np.testing.assert_allclose(
            got[b], want, rtol=1e-4, atol=1e-5 * float(np.abs(want).max())
        )


# --------------------------------------------------------- insert_batch
def _brute_topk(idx, q, k):
    return set(np.argsort(idx.batch_distances(q))[:k].tolist())


def _recall(idx, queries, k=5, ef=64):
    hits = 0
    for q in queries:
        got = {v for _, v in idx.search(q, k=k, ef=ef)}
        hits += len(got & _brute_topk(idx, q, k))
    return hits / (k * len(queries))


def test_insert_batch_recall_parity():
    """Batched construction must match sequential construction's recall@k
    on a fixed query set within tolerance, with exact distance parity vs
    the seed oracle (the acceptance bar)."""
    rng = np.random.default_rng(5)
    dim, n = 128, 300
    data = rng.normal(0, 1, (n, dim))
    seq = HNSWIndex(dim, m=8, ef_construction=32, seed=7)
    for row in data:
        seq.insert(row)
    bat = HNSWIndex(dim, m=8, ef_construction=32, seed=7)
    vids = bat.insert_batch(data)
    assert vids == list(range(n)) and len(bat) == n
    # identical quantized payloads (same codes → same stored bases)
    assert np.array_equal(bat._codes[:n], seq._codes[:n])
    np.testing.assert_array_equal(bat._scales[:n], seq._scales[:n])
    # distances from the batch-built index match the dense seed oracle
    for q in rng.normal(0, 1, (5, dim)):
        want = quantized_l2_batch_dense(
            q, bat._codes[:n], bat._scales[:n], bat._zps[:n], bat._mids[:n]
        )
        np.testing.assert_allclose(bat.batch_distances(q), want, rtol=1e-6)
    queries = rng.normal(0, 1, (40, dim))
    r_seq = _recall(seq, queries)
    r_bat = _recall(bat, queries)
    assert r_bat >= r_seq - 0.05, (r_bat, r_seq)


def test_insert_batch_incremental_and_chunked():
    """Batches onto a non-empty index, tiny-chunk matrices, empty batch."""
    rng = np.random.default_rng(6)
    dim = 48
    data = rng.normal(0, 1, (90, dim))
    idx = HNSWIndex(dim, m=8, ef_construction=32, seed=1)
    assert idx.insert_batch(np.empty((0, dim))) == []
    assert idx.insert_batch([]) == []
    idx.insert_batch(data[:30])
    # force many matrix chunks (cols grow mid-batch)
    idx.insert_batch(data[30:], max_matrix_elems=64)
    assert len(idx) == 90
    assert _recall(idx, rng.normal(0, 1, (20, dim))) > 0.8
    # serialization survives batched construction
    again = HNSWIndex.from_bytes(idx.to_bytes())
    q = rng.normal(0, 1, dim)
    assert [v for _, v in again.search(q, k=3)] == [
        v for _, v in idx.search(q, k=3)
    ]


def test_insert_batch_levels_match_sequential_rng():
    """Level draws consume the RNG in per-item order: same seed → same
    level assignment as sequential inserts."""
    rng = np.random.default_rng(7)
    dim = 16
    data = rng.normal(0, 1, (60, dim))
    seq = HNSWIndex(dim, seed=3)
    for row in data:
        seq.insert(row)
    bat = HNSWIndex(dim, seed=3)
    bat.insert_batch(data)
    assert bat._levels == seq._levels


def test_nearest_live_batch_masks_tombstones():
    rng = np.random.default_rng(8)
    dim = 32
    idx = HNSWIndex(dim, seed=0)
    data = rng.normal(0, 1, (20, dim))
    idx.insert_batch(data)
    vids, dists = idx.nearest_live_batch(data[:4] + 1e-9)
    assert vids.tolist() == [0, 1, 2, 3]
    assert (dists < 1.0).all()
    idx.mark_deleted(2)
    vids2, _ = idx.nearest_live_batch(data[2:3])
    assert vids2[0] != 2
    for v in range(20):
        idx.mark_deleted(v)
    vids3, dists3 = idx.nearest_live_batch(data[:2])
    assert vids3.tolist() == [-1, -1] and np.isinf(dists3).all()


def test_insert_batch_matches_insert_on_engine_roundtrip(tmp_path):
    """A model saved through the batched engine path reconstructs within
    the paper's tolerance bound, in input order."""
    rng = np.random.default_rng(9)
    eng = StorageEngine(str(tmp_path))
    tensors = {
        f"l{i}/{p}": rng.normal(0, 0.02, (12, 12) if p == "w" else (12,))
        .astype(np.float32)
        for i in range(3)
        for p in ("w", "b")
    }
    eng.save_model("m", {}, tensors)
    lm = eng.load_model("m")
    assert lm.tensor_names() == list(tensors)
    out = lm.materialize()
    for k, v in tensors.items():
        assert np.abs(out[k] - v).max() <= TOL


def test_probe_falls_back_to_graph_descent_on_grown_index(tmp_path, monkeypatch):
    """Thin groups against a grown index must use the HNSW descent, not a
    full brute-force scan — and still dedup/load correctly."""
    import repro.core.engine as engmod
    monkeypatch.setattr(engmod, "BRUTE_PROBE_MAX_INDEX", 4)
    monkeypatch.setattr(engmod, "BRUTE_PROBE_GROUP_FACTOR", 1)
    rng = np.random.default_rng(20)
    eng = StorageEngine(str(tmp_path))
    base = {"w": rng.normal(0, 5.0, 64).astype(np.float32)}
    for i in range(6):  # grow the dim-64 index past the (patched) cutoff
        eng.save_model(f"b{i}", {}, {"w": rng.normal(0, 5.0, 64)
                                     .astype(np.float32)})
    eng.save_model("base", {}, base)
    ft = {"w": (base["w"] + rng.normal(0, 1e-5, 64)).astype(np.float32)}
    r = eng.save_model("ft", {}, ft)  # descent path: must still find base
    assert r.n_new_bases == 0
    out = eng.load_model("ft").materialize()
    assert np.abs(out["w"] - ft["w"]).max() <= TOL


def test_intra_save_dedup_matches_sequential_semantics(tmp_path):
    """Two mutually-similar tensors that are dissimilar from every resident
    base must produce ONE new vertex (the second becomes a delta), as the
    sequential per-tensor path did."""
    rng = np.random.default_rng(10)
    eng = StorageEngine(str(tmp_path))
    t1 = rng.normal(0, 5.0, 200).astype(np.float32)
    t2 = (t1 + rng.normal(0, 1e-5, 200)).astype(np.float32)
    t3 = rng.normal(0, 5.0, 200).astype(np.float32)  # dissimilar from both
    r = eng.save_model("m", {}, {"a": t1, "b": t2, "c": t3})
    assert r.n_new_bases == 2 and r.n_deltas == 1
    out = eng.load_model("m").materialize()
    for k, v in {"a": t1, "b": t2, "c": t3}.items():
        assert np.abs(out[k] - v).max() <= TOL


# ----------------------------------------------------------- save_models
def _family(rng, n_models, dim=64):
    base = {"w0": rng.normal(0, 0.02, dim).astype(np.float32),
            "w1": rng.normal(0, 0.02, dim // 2).astype(np.float32)}
    out = [("base", {"kind": "base"}, base)]
    for i in range(n_models - 1):
        out.append((
            f"ft{i}", {},
            {k: v + rng.normal(0, 1e-5, v.shape).astype(np.float32)
             for k, v in base.items()},
        ))
    return out


def test_save_models_one_transaction_shared_bases(tmp_path):
    rng = np.random.default_rng(11)
    eng = StorageEngine(str(tmp_path))
    specs = _family(rng, 4)
    reports = eng.save_models(specs)
    assert [r.name for r in reports] == [s[0] for s in specs]
    # fine-tunes dedup against the bases the batch itself created
    assert reports[0].n_new_bases == 2
    assert all(r.n_new_bases == 0 for r in reports[1:])
    assert len({r.model_id for r in reports}) == 4
    for name, _a, tensors in specs:
        out = eng.load_model(name).materialize()
        for k, v in tensors.items():
            assert np.abs(out[k] - v).max() <= TOL
    # reopen: committed, journal clean
    eng2 = StorageEngine(str(tmp_path))
    assert sorted(eng2.list_models()) == sorted(s[0] for s in specs)
    assert eng2.catalog.pending() == []


def test_save_models_journals_single_intent(tmp_path):
    """The whole batch rides one journal intent (one fsync'd begin)."""
    rng = np.random.default_rng(12)
    eng = StorageEngine(str(tmp_path))
    catmod.FAILPOINTS.add("save_batch.after_intent")
    with pytest.raises(InjectedCrash):
        eng.save_models(_family(rng, 3))
    with open(os.path.join(str(tmp_path), "journal.jsonl")) as f:
        recs = [json.loads(line) for line in f if line.strip()]
    assert len(recs) == 1
    assert recs[0]["op"] == "save_batch"
    assert len(recs[0]["models"]) == 3


def test_save_models_rejects_duplicate_names(tmp_path):
    rng = np.random.default_rng(13)
    eng = StorageEngine(str(tmp_path))
    t = {"w": rng.normal(0, 1, 16).astype(np.float32)}
    with pytest.raises(ValueError):
        eng.save_models([("m", {}, t), ("m", {}, t)])
    assert eng.save_models([]) == []


def _assert_consistent(eng):
    """No orphan pages, no dangling refs, every model materializes."""
    pages_dir = os.path.join(eng.root, "pages")
    on_disk = set(os.listdir(pages_dir))
    referenced = {eng.catalog.get(n).page for n in eng.list_models()}
    assert on_disk == referenced, f"orphan pages: {on_disk - referenced}"
    derived = Counter()
    for name in eng.list_models():
        derived.update(eng._page_refs(eng.catalog.get(name).page))
    table = {
        tuple(map(int, k.split(":"))): v
        for k, v in eng.catalog.state.vertex_refs.items()
    }
    assert table == dict(derived)
    for name in eng.list_models():
        eng.load_model(name).materialize()


@pytest.mark.parametrize("point", [
    "save_batch.after_intent",
    "save_batch.after_index_flush",
    "save_batch.after_page_write",
    "save_batch.after_snapshot",
])
def test_save_models_crash_is_all_or_nothing(tmp_path, point):
    """A crash at any protocol step replays to every model committed or
    none of them — never a partial batch."""
    rng = np.random.default_rng(14)
    eng = StorageEngine(str(tmp_path))
    eng.save_model("pre", {}, {"w": rng.normal(0, 5.0, 48).astype(np.float32)})
    specs = _family(rng, 3, dim=48)
    catmod.FAILPOINTS.add(point)
    with pytest.raises(InjectedCrash):
        eng.save_models(specs)
    catmod.FAILPOINTS.clear()
    eng2 = StorageEngine(str(tmp_path))
    names = set(eng2.list_models())
    batch = {s[0] for s in specs}
    assert "pre" in names
    committed = names & batch
    assert committed in (set(), batch), f"partial batch survived: {committed}"
    if point == "save_batch.after_snapshot":
        assert committed == batch  # snapshot switched → rolled forward
    _assert_consistent(eng2)


@pytest.mark.parametrize("point", [
    "save_batch.after_intent",
    "save_batch.after_snapshot",
])
def test_save_models_replace_crash_all_or_nothing(tmp_path, point):
    """Replaces inside a batch roll with the batch: old versions survive a
    pre-commit crash and are fully dropped after a post-commit crash."""
    rng = np.random.default_rng(15)
    eng = StorageEngine(str(tmp_path))
    v1 = {"w": rng.normal(0, 5.0, 40).astype(np.float32)}
    eng.save_model("m0", {}, v1)
    snap_v1 = eng.load_model("m0").materialize()
    v2 = {"w": rng.normal(0, 5.0, 40).astype(np.float32)}
    fresh = {"w": rng.normal(0, 5.0, 40).astype(np.float32)}
    catmod.FAILPOINTS.add(point)
    with pytest.raises(InjectedCrash):
        eng.save_models([("m0", {}, v2), ("m1", {}, fresh)])
    catmod.FAILPOINTS.clear()
    eng2 = StorageEngine(str(tmp_path))
    _assert_consistent(eng2)
    out = eng2.load_model("m0").materialize()
    if point == "save_batch.after_intent":
        assert "m1" not in eng2.list_models()
        assert np.array_equal(out["w"], snap_v1["w"])  # old version intact
    else:
        assert "m1" in eng2.list_models()
        assert np.abs(out["w"] - v2["w"]).max() <= TOL  # new version live


# ------------------------------------------------------- multi-save loading
def test_load_models_materialize_many_shared_dequant(tmp_path, monkeypatch):
    rng = np.random.default_rng(16)
    eng = StorageEngine(str(tmp_path))
    specs = _family(rng, 3, dim=80)
    eng.save_models(specs)
    want = {n: eng.load_model(n).materialize() for n, _a, _t in specs}

    import repro.core.loader as loader_mod
    calls = Counter()
    real = loader_mod.dequantize_linear

    def counting(codes, meta):
        calls["n"] += 1
        return real(codes, meta)

    monkeypatch.setattr(loader_mod, "dequantize_linear", counting)
    handles = eng.load_models([n for n, _a, _t in specs])
    outs = materialize_many(handles)
    # 2 distinct bases shared by 3 handles → dequantized once each, not 6×
    assert calls["n"] == 2
    for (name, _a, _t), out in zip(specs, outs):
        for k in want[name]:
            assert np.array_equal(out[k], want[name][k])
