"""Hot-path regression tests: vectorized HNSW vs the frozen seed oracle,
capacity growth, dirty-aware index flushing, and planar bitpack parity."""

import os
import pickle

import numpy as np
import pytest

from repro.core.bitpack import (
    pack_bits_planar,
    planar_plane_bytes,
    unpack_bits_planar,
)
from repro.core.engine import StorageEngine
from repro.core.hnsw import HNSWIndex, quantized_l2_batch
from repro.core.hnsw_ref import SeedHNSWIndex, quantized_l2_batch_dense

RNG = np.random.default_rng(11)


# --------------------------------------------------------- search parity
@pytest.mark.parametrize("dim,n", [(64, 150), (300, 80)])
def test_insert_search_parity_vs_seed(dim, n):
    """Same fixed-seed workload → identical vertex ids, identical neighbor
    ids, distances within 1e-6 relative of the seed oracle."""
    rng = np.random.default_rng(dim + n)
    new = HNSWIndex(dim, m=8, ef_construction=32, seed=5)
    old = SeedHNSWIndex(dim, m=8, ef_construction=32, seed=5)
    for row in rng.normal(0, 1, (n, dim)):
        assert new.insert(row) == old.insert(row)
    for _ in range(25):
        q = rng.normal(0, 1, dim)
        got = new.search(q, k=5)
        want = old.search(q, k=5)
        assert [v for _, v in got] == [v for _, v in want]
        gd = np.array([d for d, _ in got])
        wd = np.array([d for d, _ in want])
        np.testing.assert_allclose(gd, wd, rtol=1e-6)


def test_batch_distance_matches_dense_oracle():
    rng = np.random.default_rng(3)
    n, d = 200, 513
    codes = rng.integers(0, 256, (n, d)).astype(np.uint8)
    scales = rng.uniform(1e-3, 2e-2, n)
    scales[7] = 0.0  # constant-row path
    zps = rng.integers(0, 256, n).astype(np.int64)
    mids = rng.normal(0, 0.5, n)
    q = rng.normal(0, 1, d)
    want = quantized_l2_batch_dense(q, codes, scales, zps, mids)
    got = quantized_l2_batch(q, codes, scales, zps, mids)
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-9)


def test_index_batch_distances_match_oracle():
    rng = np.random.default_rng(4)
    dim = 128
    idx = HNSWIndex(dim, seed=0)
    for row in rng.normal(0, 1, (40, dim)):
        idx.insert(row)
    idx.insert(np.full(dim, 0.75))  # constant vertex: scale == 0 path
    q = rng.normal(0, 1, dim)
    n = len(idx)
    want = quantized_l2_batch_dense(
        q, idx._codes[:n], idx._scales[:n], idx._zps[:n], idx._mids[:n]
    )
    np.testing.assert_allclose(idx.batch_distances(q), want, rtol=1e-6)


def test_near_duplicate_query_ranking_and_abs_error():
    """Near a stored vertex the decomposed distance loses *relative*
    precision (f32 dot) but keeps a small absolute error, so nearest-base
    ranking — all the engine consumes — is preserved."""
    rng = np.random.default_rng(21)
    dim = 2048
    idx = HNSWIndex(dim, seed=0)
    rows = rng.normal(0, 1, (8, dim))
    for r in rows:
        idx.insert(r)
    q = rows[5] + rng.normal(0, 1e-5, dim)
    n = len(idx)
    truth = quantized_l2_batch_dense(
        q, idx._codes[:n], idx._scales[:n], idx._zps[:n], idx._mids[:n]
    )
    got = idx.batch_distances(q)
    assert int(np.argmin(got)) == int(np.argmin(truth)) == 5
    assert abs(got[5] - truth[5]) < 1e-2  # absolute error stays tiny...
    assert sorted(truth)[1] > 100.0       # ...vs a huge ranking margin
    assert idx.search(q, k=1)[0][1] == 5


# ------------------------------------------------------- capacity growth
def test_capacity_doubling_preserves_vertices():
    """Vertex payloads must survive every array reallocation boundary."""
    rng = np.random.default_rng(9)
    dim = 32
    idx = HNSWIndex(dim, m=4, seed=2)
    rows = rng.normal(0, 1, (70, dim))  # crosses 8 → 16 → 32 → 64 → 128
    snapshots = {}
    caps = set()
    for i, row in enumerate(rows):
        vid = idx.insert(row)
        assert vid == i
        snapshots[vid] = idx.dequantize_vertex(vid).copy()
        caps.add(idx._cap)
    assert len(idx) == 70
    assert idx._cap >= 70 and len(caps) > 1, "growth path never exercised"
    for vid, snap in snapshots.items():
        np.testing.assert_array_equal(idx.dequantize_vertex(vid), snap)
    # cached norms stay consistent with the stored codes after growth
    for vid in (0, 7, 8, 63, 69):
        deq = idx.dequantize_vertex(vid)
        assert idx._norms[vid] == pytest.approx(float(deq @ deq), rel=1e-12)


def test_nbytes_counts_all_vertex_arrays():
    idx = HNSWIndex(16, seed=0)
    for row in np.random.default_rng(1).normal(0, 1, (10, 16)):
        idx.insert(row)
    floor = (
        idx._codes.nbytes + idx._scales.nbytes + idx._zps.nbytes
        + idx._mids.nbytes + idx._norms.nbytes
    )
    assert idx.nbytes >= floor  # mids (and norms) included, plus edges


def test_from_bytes_accepts_seed_format():
    """Old pickles (list adjacency, no cached norms) must still load."""
    rng = np.random.default_rng(6)
    dim = 24
    old = SeedHNSWIndex(dim, m=8, ef_construction=32, seed=7)
    for row in rng.normal(0, 1, (30, dim)):
        old.insert(row)
    state = {
        "dim": old.dim,
        "m": old.m,
        "ef_construction": old.ef_construction,
        "codes": old._codes,
        "scales": old._scales,
        "zps": old._zps,
        "mids": old._mids,
        "levels": old._levels,
        "neighbors": old._neighbors,
        "entry": old._entry,
        "max_level": old._max_level,
    }
    idx = HNSWIndex.from_bytes(pickle.dumps(state))
    for _ in range(10):
        q = rng.normal(0, 1, dim)
        got = idx.search(q, k=3)
        want = old.search(q, k=3)
        assert [v for _, v in got] == [v for _, v in want]
        np.testing.assert_allclose(
            [d for d, _ in got], [d for d, _ in want], rtol=1e-6
        )


# ------------------------------------------------------------ dirty flush
def _idx_file(root, dim):
    return os.path.join(root, "index", f"hnsw_{dim}.idx")


def test_save_reserializes_only_mutated_index(tmp_path):
    """Acceptance: a save mutating one dim's index rewrites only that file."""
    rng = np.random.default_rng(2)
    eng = StorageEngine(str(tmp_path))
    t64 = rng.normal(0, 0.02, 64).astype(np.float32)
    t100 = rng.normal(0, 0.02, 100).astype(np.float32)
    eng.save_model("m0", {}, {"a": t64, "b": t100})
    with open(_idx_file(str(tmp_path), 64), "rb") as f:
        blob64 = f.read()
    with open(_idx_file(str(tmp_path), 100), "rb") as f:
        blob100 = f.read()
    # Dissimilar dim-100 tensor → new vertex in the dim-100 index only;
    # dim-64 tensor is a tiny fine-tune → pure delta, index untouched.
    eng.save_model(
        "m1", {},
        {"a": t64 + rng.normal(0, 1e-5, 64).astype(np.float32),
         "b": rng.normal(0, 5.0, 100).astype(np.float32)},
    )
    with open(_idx_file(str(tmp_path), 64), "rb") as f:
        assert f.read() == blob64, "clean index was reserialized"
    with open(_idx_file(str(tmp_path), 100), "rb") as f:
        assert f.read() != blob100, "mutated index was not reserialized"
    # And both models still reconstruct.
    for name in ("m0", "m1"):
        eng.load_model(name).materialize()


def test_unchanged_save_flushes_nothing(tmp_path):
    rng = np.random.default_rng(12)
    eng = StorageEngine(str(tmp_path))
    base = {"w": rng.normal(0, 0.02, 80).astype(np.float32)}
    eng.save_model("base", {}, base)
    flushes_after_first = eng.index_cache.stats()["dirty_flushes"]
    r = eng.save_model(
        "ft", {}, {"w": base["w"] + rng.normal(0, 1e-5, 80).astype(np.float32)}
    )
    assert r.n_new_bases == 0
    assert eng.index_cache.stats()["dirty_flushes"] == flushes_after_first


def test_pinned_index_survives_eviction(tmp_path):
    """A save's in-flight index must not be evicted by concurrent gets."""
    rng = np.random.default_rng(0)
    eng = StorageEngine(str(tmp_path), cache_bytes=1)  # evict on every get
    cache = eng.index_cache
    idx64 = cache.get(64, create=True)
    idx64.insert(rng.normal(0, 1, 64))  # nonzero nbytes → over budget
    cache.mark_dirty(64)
    cache.pin(64)
    try:
        i100 = cache.get(100, create=True)
        i100.insert(rng.normal(0, 1, 100))
        cache.mark_dirty(100)
        cache.get(200, create=True)  # evicts 100, never pinned 64
        assert 100 not in cache._live
        assert cache.get(64) is idx64, "pinned index was evicted"
    finally:
        cache.unpin(64)
    cache.get(300, create=True)
    assert 64 not in cache._live, "unpinned index should evict again"
    # the evicted dirty index was persisted, not dropped
    assert cache.get(64) is not None and len(cache.get(64)) == 1


def test_cache_stats_and_create_counts_as_miss(tmp_path):
    eng = StorageEngine(str(tmp_path))
    cache = eng.index_cache
    assert cache.get(123) is None  # absent, no create: not a hit or miss
    cache.get(123, create=True)
    assert cache.stats()["misses"] == 1
    cache.get(123)
    s = cache.stats()
    assert s["hits"] == 1 and s["misses"] == 1
    assert set(s) >= {"hits", "misses", "evictions", "dirty_flushes"}


def test_save_preserves_record_order_across_dim_grouping(tmp_path):
    """Dim-grouped index work must not reorder page records (paper §4.1)."""
    rng = np.random.default_rng(8)
    eng = StorageEngine(str(tmp_path))
    tensors = {
        "l0/w": rng.normal(0, 0.02, (8, 8)).astype(np.float32),
        "l0/b": rng.normal(0, 0.02, (8,)).astype(np.float32),
        "l1/w": rng.normal(0, 0.02, (8, 8)).astype(np.float32),
        "l1/b": rng.normal(0, 0.02, (8,)).astype(np.float32),
    }
    eng.save_model("m", {}, tensors)
    lm = eng.load_model("m")
    assert lm.tensor_names() == list(tensors)
    out = lm.materialize()
    for k, v in tensors.items():
        assert np.abs(out[k] - v).max() <= 2.0 ** -24 * 1.001 + 1e-9


def test_loader_decodes_payload_lazily(tmp_path):
    rng = np.random.default_rng(13)
    eng = StorageEngine(str(tmp_path))
    eng.save_model("m", {}, {"w": rng.normal(0, 0.02, 64).astype(np.float32)})
    lm = eng.load_model("m")
    assert lm._records["w"].qdelta is None, "decode should be deferred"
    assert lm.record("w").qdelta is not None
    np.testing.assert_allclose(
        lm.tensor("w"),
        eng.load_model("m").materialize()["w"],
    )


# --------------------------------------------------------- planar bitpack
def _pack_planar_loop(values, nbit):
    """The seed per-plane Python loop, kept inline as the reference."""
    v = np.ascontiguousarray(values.ravel(), dtype=np.uint64)
    out = bytearray()
    for k in range(nbit - 1, -1, -1):
        out += np.packbits(((v >> np.uint64(k)) & 1).astype(np.uint8)).tobytes()
    return bytes(out)


def _unpack_planar_loop(data, nbit, count, b=None):
    b = nbit if b is None else min(b, nbit)
    plane = planar_plane_bytes(count)
    buf = np.frombuffer(data, dtype=np.uint8)
    acc = np.zeros(count, dtype=np.int64)
    for k in range(b):
        bits = np.unpackbits(buf[k * plane:(k + 1) * plane], count=count)
        acc = (acc << 1) | bits.astype(np.int64)
    return acc


@pytest.mark.parametrize("nbit", [1, 7, 8, 17, 32])
@pytest.mark.parametrize("count", [1, 5, 8, 257])
def test_planar_pack_matches_loop_reference(nbit, count):
    rng = np.random.default_rng(nbit * 100 + count)
    v = rng.integers(0, 1 << nbit, count, dtype=np.uint64)
    packed = pack_bits_planar(v, nbit)
    assert packed == _pack_planar_loop(v, nbit), "on-disk layout changed"
    assert len(packed) == nbit * planar_plane_bytes(count)
    got = unpack_bits_planar(packed, nbit, count)
    np.testing.assert_array_equal(got, v.astype(np.int64))
    # Partial (MSB-prefix) reads agree with the loop reference too.
    for b in (1, nbit // 2, nbit):
        if b == 0:
            continue
        np.testing.assert_array_equal(
            unpack_bits_planar(packed, nbit, count, b=b),
            _unpack_planar_loop(packed, nbit, count, b=b),
        )
        np.testing.assert_array_equal(
            unpack_bits_planar(packed, nbit, count, b=b),
            v.astype(np.int64) >> (nbit - b),
        )
    # b=0 degrades to zeros (seed behavior), not an IndexError
    np.testing.assert_array_equal(
        unpack_bits_planar(packed, nbit, count, b=0),
        np.zeros(count, dtype=np.int64),
    )
