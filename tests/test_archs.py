"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation) — see launch/dryrun.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import decode_step, init_cache, init_params, loss_fn

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    labels = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    if cfg.frontend == "embeddings":
        emb = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model))
        return {"embeds": emb, "labels": labels}
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_loss(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    loss, metrics = loss_fn(params, _batch(cfg), cfg)
    assert np.isfinite(float(loss))
    # Random init ⇒ loss near ln(V).
    assert abs(float(loss) - np.log(cfg.vocab_size)) < 3.0


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    """One SGD step decreases loss on a fixed batch (end-to-end grad flow)."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)

    grad_fn = jax.jit(jax.value_and_grad(lambda p: loss_fn(p, batch, cfg)[0]))
    l0, g = grad_fn(params)
    finite = jax.tree.map(lambda x: bool(np.isfinite(np.asarray(x)).all()), g)
    assert all(jax.tree.leaves(finite)), "non-finite gradients"
    params2 = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    l1, _ = grad_fn(params2)
    assert float(l1) < float(l0), (arch, float(l0), float(l1))


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_config(a, smoke=True).has_decode])
def test_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    b = 2
    cache = init_cache(cfg, b, 128)
    tokens = jnp.zeros((b, 1), jnp.int32)
    logits, cache = decode_step(params, cache, {"tokens": tokens},
                                jnp.int32(0), cfg)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    # A second step at the next position must also be finite & well-shaped.
    logits2, _ = decode_step(params, cache, {"tokens": tokens},
                             jnp.int32(1), cfg)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", [a for a in list_archs()
                                  if get_config(a, smoke=True).has_decode])
def test_prefill_decode_consistency(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    b, s = 1, 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    from repro.models import forward

    batch = ({"tokens": toks} if cfg.frontend != "embeddings"
             else {"tokens": toks})
    full_logits = forward(params, batch, cfg)
    cache = init_cache(cfg, b, s)
    step_logits = []
    for t in range(s):
        lg, cache = decode_step(params, cache, {"tokens": toks[:, t:t + 1]},
                                jnp.int32(t), cfg)
        step_logits.append(lg)
    step_logits = jnp.concatenate(step_logits, axis=1)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits), rtol=2e-2, atol=2e-3)


def test_param_counts_match_published():
    """Full configs land on the published parameter counts."""
    expect = {
        "deepseek-67b": (67e9, 0.05),
        "arctic-480b": (480e9, 0.05),
        "qwen3-8b": (8.2e9, 0.1),
        "glm4-9b": (9.4e9, 0.1),
        "rwkv6-7b": (7.6e9, 0.1),
        "llava-next-34b": (34e9, 0.05),
        "internlm2-1.8b": (1.9e9, 0.1),
    }
    for arch, (n, tol) in expect.items():
        got = get_config(arch).n_params
        assert abs(got - n) / n < tol, (arch, got, n)
