"""Concurrent read path: snapshot isolation, lock-free materialization,
engine stats counters, and the background maintenance daemon.

The headline (acceptance) test: a reader that opened a model before a
concurrent ``replace_model`` + ``vacuum`` still materializes the OLD
weights bit-identically from its pinned snapshot — old page bytes, old
index object — while a reader opening after the writer's commit sees the
new weights; and no reader holds the engine lock during dequantization
(proved by materializing while another thread owns the lock).

Run with ``PYTHONFAULTHANDLER=1`` (the CI thread-stress step does) so a
deadlock dumps tracebacks instead of hanging the job.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import StorageEngine
from repro.core.loader import materialize_many
from repro.core.maintenance import MaintenanceDaemon

RNG = np.random.default_rng(23)


def _model(scale=5.0, d=64):
    return {
        "w": RNG.normal(0, scale, (d, d)).astype(np.float32),
        "b": RNG.normal(0, scale, (d,)).astype(np.float32),
    }


# --------------------------------------------------------- snapshot isolation
def test_snapshot_isolation_across_replace_and_vacuum(tmp_path):
    """The acceptance bar: old-snapshot readers keep the old weights
    bit-identically across replace+vacuum; post-commit readers see new."""
    eng = StorageEngine(str(tmp_path))
    eng.save_model("m", {}, _model())
    old_weights = eng.load_model("m").materialize()

    reader = eng.load_model("m")  # snapshot captured BEFORE the writes
    new_tensors = _model()
    eng.replace_model("m", {}, new_tensors)
    rep = eng.vacuum()  # drops the old version's now-unreferenced bases
    assert rep["vertices_dropped"] > 0

    # Old snapshot: bit-identical old weights, lock-free (see below).
    out = reader.materialize()
    for k in old_weights:
        assert np.array_equal(out[k], old_weights[k])

    # New reader: the replacement, not the snapshot.
    fresh = eng.load_model("m").materialize()
    for k in new_tensors:
        assert np.abs(fresh[k] - new_tensors[k]).max() <= 2.0 ** -24 * 1.001 + 1e-9
        assert not np.array_equal(fresh[k], old_weights[k])


def test_reader_never_takes_engine_lock_during_dequant(tmp_path):
    """Hold the engine lock in this thread; a snapshot reader in another
    thread must still complete materialize() — i.e. the read path is
    lock-free after capture."""
    eng = StorageEngine(str(tmp_path))
    eng.save_model("m", {}, _model())
    lm = eng.load_model("m")
    result: dict = {}

    def read():
        result["out"] = lm.materialize()
        cp = lm.compressed_params()
        result["params"] = {name: cp[name] for name in cp}

    t = threading.Thread(target=read)
    with eng._lock:  # a writer mid-commit, as far as readers can tell
        t.start()
        t.join(timeout=30)
        assert not t.is_alive(), "materialize() blocked on the engine lock"
    assert set(result["out"]) == {"w", "b"}
    assert set(result["params"]) == {"w", "b"}


def test_snapshot_entry_is_immune_to_vacuum_renames(tmp_path):
    """The snapshot's catalog row is a copy: vacuum re-pointing the live
    entry at a rewritten page must not change what an open handle says it
    pinned (lm.info.page names the bytes the snapshot actually holds)."""
    eng = StorageEngine(str(tmp_path))
    eng.save_model("dead", {}, _model())
    eng.save_model("m", {}, _model())
    lm = eng.load_model("m")
    pinned_page = lm.info.page
    eng.delete_model("dead")
    rep = eng.vacuum()  # renumbers m's vertices → rewrites m's page
    assert rep["pages_rewritten"] >= 1
    assert lm.info.page == pinned_page                 # snapshot view
    assert eng.model_info("m").page != pinned_page     # live catalog moved
    lm.materialize()


def test_snapshot_epoch_advances_with_writer_commits(tmp_path):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("a", {}, _model())
    e1 = eng.stats()["epoch"]
    lm = eng.load_model("a")
    assert lm.snapshot.epoch == e1
    eng.save_model("b", {}, _model())
    e2 = eng.stats()["epoch"]
    assert e2 > e1
    eng.delete_model("b")
    assert eng.stats()["epoch"] > e2
    # The old handle still pins the oldest epoch.
    assert eng.stats()["snapshots"]["oldest_epoch"] == e1
    lm.close()
    stats = eng.stats()
    assert stats["snapshots"]["live"] == 0
    assert stats["snapshots"]["oldest_epoch"] is None


def test_concurrent_readers_and_writer_thread_stress(tmp_path):
    """4 reader threads materialize models while a writer replaces and
    deletes concurrently; every read must be internally consistent (a
    version the catalog committed at some point, never a mix)."""
    eng = StorageEngine(str(tmp_path))
    versions: dict[str, list[dict]] = {}
    for name in ("m0", "m1"):
        t = _model()
        eng.save_model(name, {}, t)
        versions[name] = [eng.load_model(name).materialize()]

    stop = threading.Event()
    errors: list[str] = []
    version_lock = threading.Lock()

    def writer():
        k = 0
        while not stop.is_set():
            name = f"m{k % 2}"
            new = _model()
            eng.replace_model(name, {}, new)
            with version_lock:
                versions[name].append(eng.load_model(name).materialize())
            eng.vacuum()
            k += 1
            time.sleep(0.002)

    def reader(seed: int):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            name = f"m{rng.integers(2)}"
            try:
                out = eng.load_model(name).materialize()
            except KeyError:
                continue
            with version_lock:
                known = list(versions[name])
            ok = any(
                all(np.array_equal(out[k], v[k]) for k in out)
                for v in known
            )
            if not ok:
                errors.append(f"{name}: read a state no commit produced")
                stop.set()
                return

    threads = [threading.Thread(target=writer)] + [
        threading.Thread(target=reader, args=(s,)) for s in range(4)
    ]
    for t in threads:
        t.start()
    time.sleep(2.0)
    stop.set()
    for t in threads:
        t.join(60)
    assert not any(t.is_alive() for t in threads), "stress deadlocked"
    assert not errors, errors
    # The store is still consistent and serves both models.
    for name in ("m0", "m1"):
        eng.load_model(name).materialize()


# ------------------------------------------------------------ stats satellite
def test_engine_stats_expose_pool_and_snapshot_counters(tmp_path):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("m", {}, _model())
    lm1 = eng.load_model("m")
    lm1.materialize()
    lm2 = eng.load_model("m")
    lm2.materialize()
    stats = eng.stats()
    pool = stats["buffer_pool"]
    assert pool["misses"] == 1          # one page read for both handles
    assert pool["hits"] >= 1            # second handle hit the frame
    assert pool["decoded_misses"] == 2  # two records decoded once...
    assert pool["decoded_hits"] >= 2    # ...and shared with handle 2
    assert pool["pinned_bytes"] > 0     # live handles pin their frame
    assert pool["resident_bytes"] >= pool["pinned_bytes"] or pool["detached"]
    assert stats["epoch"] >= 1
    assert stats["snapshots"]["live"] == 2
    assert stats["index_cache"]["resident"] >= 1
    lm1.close()
    lm2.close()
    assert eng.stats()["buffer_pool"]["pinned_bytes"] == 0


def test_pool_eviction_under_tiny_budget(tmp_path):
    eng = StorageEngine(str(tmp_path), pool_bytes=1)
    eng.save_model("a", {}, _model())
    eng.save_model("b", {}, _model())
    a = eng.load_model("a").materialize()
    eng.load_model("b").materialize()
    stats = eng.stats()["buffer_pool"]
    assert stats["evictions"] >= 1
    assert stats["resident_bytes"] <= max(1, stats["pinned_bytes"])
    # Evicted pages reload transparently and identically.
    again = eng.load_model("a").materialize()
    for k in a:
        assert np.array_equal(again[k], a[k])


def test_materialize_many_shares_bases_lock_free(tmp_path):
    eng = StorageEngine(str(tmp_path))
    base = _model(scale=0.02)
    eng.save_model("base", {}, base)
    ft = {k: v + RNG.normal(0, 3e-4, v.shape).astype(np.float32)
          for k, v in base.items()}
    r = eng.save_model("ft", {}, ft)
    assert r.n_new_bases == 0
    handles = eng.load_models(["base", "ft"])
    with eng._lock:  # cross-handle sharing must not need the engine lock
        done: dict = {}
        t = threading.Thread(
            target=lambda: done.update(out=materialize_many(handles)))
        t.start()
        t.join(30)
        assert not t.is_alive()
    outs = done["out"]
    for k, v in base.items():
        assert np.abs(outs[0][k] - v).max() <= 2.0 ** -24 * 1.001 + 1e-9


# -------------------------------------------------------- maintenance daemon
def test_maintenance_step_runs_incremental_vacuum(tmp_path):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("keep", {}, _model())
    eng.save_model("dead1", {}, _model())
    eng.save_model("dead2", {}, _model())
    eng.delete_model("dead1")
    eng.delete_model("dead2")
    daemon = MaintenanceDaemon(eng, dead_fraction=0.25)
    # Deterministic synchronous stepping: one dim-group per step.
    dims = eng.index_cache.dims()
    dropped = 0
    reports = [daemon.step() for _ in range(len(dims))]
    dropped = sum(r["vertices_dropped"] for r in reports)
    assert dropped == 4  # both dead models' bases, both dims
    assert {r["dim_checked"] for r in reports} == set(dims)  # round-robin
    assert daemon.steps == len(dims)
    assert daemon.stats()["vacuumed_vertices"] == 4
    # Survivor is untouched.
    eng.load_model("keep").materialize()
    # A further step finds nothing to do.
    assert daemon.step()["vertices_dropped"] == 0


def test_maintenance_step_respects_dead_fraction_threshold(tmp_path):
    eng = StorageEngine(str(tmp_path))
    for i in range(4):
        eng.save_model(f"m{i}", {}, _model())
    eng.delete_model("m3")  # 1/4 dead per dim < 0.5 threshold
    daemon = MaintenanceDaemon(eng, dead_fraction=0.5)
    for _ in eng.index_cache.dims():
        assert daemon.step()["vertices_dropped"] == 0


def test_maintenance_step_trims_pool_pressure(tmp_path):
    eng = StorageEngine(str(tmp_path), pool_bytes=4096)
    for i in range(6):
        eng.save_model(f"m{i}", {}, _model())
    for i in range(6):
        eng.load_model(f"m{i}").materialize()  # handles dropped → unpinned
    daemon = MaintenanceDaemon(eng, pool_high_watermark=0.0)
    rep = daemon.step()
    assert rep["pool_bytes_trimmed"] > 0 or \
        eng.page_pool.resident_bytes() == 0


def test_maintenance_daemon_thread_lifecycle(tmp_path):
    eng = StorageEngine(str(tmp_path))
    eng.save_model("a", {}, _model())
    eng.save_model("b", {}, _model())
    eng.delete_model("b")
    daemon = eng.start_maintenance(dead_fraction=0.1, interval_s=0.01)
    assert daemon.running
    assert eng.start_maintenance() is daemon  # idempotent
    deadline = time.monotonic() + 30
    while daemon.stats()["vacuumed_vertices"] < 2:
        if time.monotonic() > deadline:
            pytest.fail(f"daemon made no progress: {daemon.stats()}")
        time.sleep(0.01)
    assert daemon.errors == 0, daemon.last_error
    eng.close()
    assert not daemon.running
    assert eng.maintenance is None
    eng.load_model("a").materialize()  # store healthy after daemon work


def test_maintenance_skips_dims_with_inflight_saves(tmp_path):
    """The daemon's vacuum must coexist with writers: engine.vacuum already
    skips dims an in-flight save pins; a daemon running at full tilt while
    models save and delete must never corrupt the store."""
    eng = StorageEngine(str(tmp_path))
    eng.save_model("m0", {}, _model())
    daemon = eng.start_maintenance(dead_fraction=0.0, interval_s=0.001)
    for i in range(1, 12):
        eng.save_model(f"m{i}", {}, _model())
        if i % 3 == 0:
            eng.delete_model(f"m{i - 1}")
    eng.close()
    assert daemon.errors == 0, daemon.last_error
    for name in eng.list_models():
        eng.load_model(name).materialize()
