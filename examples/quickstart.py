"""Quickstart: save two related models into NeurStore, load one back
compression-aware, and run a compute-on-compressed matmul.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import StorageEngine
from repro.kernels import dequant_matmul

rng = np.random.default_rng(0)

with tempfile.TemporaryDirectory() as root:
    engine = StorageEngine(root)

    # A "pretrained" model and a fine-tune of it.
    base = {"proj/w": rng.normal(0, 0.02, (256, 256)).astype(np.float32)}
    ft = {"proj/w": base["proj/w"] + rng.normal(0, 3e-4, (256, 256)).astype(np.float32)}

    r0 = engine.save_model("pretrained", {"family": "demo"}, base)
    r1 = engine.save_model("finetune", {"family": "demo"}, ft)
    print(f"pretrained: {r0.n_new_bases} new bases, page {r0.page_bytes}B")
    print(f"finetune:   {r1.n_new_bases} new bases (deduped!), page {r1.page_bytes}B, "
          f"ratio {r1.original_bytes / r1.page_bytes:.2f}x, mean {r1.mean_nbit:.1f} bits/weight")

    # Compression-aware load: quantized components, no full decompress.
    lm = engine.load_model("finetune", bits=8)   # flexible 8-bit loading
    comp = lm.compressed_params()["proj/w"]

    # Compute directly on the compressed tensor (fused dequant+matmul —
    # on TPU the f32 weight never exists in HBM).
    x = rng.normal(0, 1, (8, 256)).astype(np.float32)
    y = dequant_matmul(
        jnp.asarray(x), jnp.asarray(comp["base_codes"]),
        comp["base_scale"], comp["base_zp"],
        jnp.asarray(comp["qdelta_i8"]),
        comp["delta_scale"], comp["delta_zp_i8"])
    y_ref = x @ ft["proj/w"]
    err = np.abs(np.asarray(y) - y_ref).max() / np.abs(y_ref).max()
    print(f"compute-on-compressed rel err: {err:.2e}")
    print(f"storage: {engine.storage_bytes()}")
