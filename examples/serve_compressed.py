"""Compression-aware serving: store a small LM in NeurStore, reload it with
flexible 8-bit deltas, and decode tokens computing directly on quantized
weights — reconstruction error stays bounded and generation matches.

    PYTHONPATH=src python examples/serve_compressed.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params

cfg = get_config("qwen3-8b", smoke=True)
params = init_params(cfg, jax.random.PRNGKey(0))

with tempfile.TemporaryDirectory() as root:
    mgr = CheckpointManager(root)
    mgr.save(0, params)

    # Full-precision restore vs flexible 8-bit restore.
    _, exact = mgr.restore()
    _, flex = mgr.restore(bits=8)

    def decode_n(p_tree, n=16):
        p = jax.tree.map(jnp.asarray, p_tree)
        cache = init_cache(cfg, 2, 64)
        toks = jnp.zeros((2, 1), jnp.int32)
        out = []
        for t in range(n):
            logits, cache = decode_step(p, cache, {"tokens": toks},
                                        jnp.int32(t), cfg)
            toks = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(np.asarray(toks))
        return np.concatenate(out, 1)

    g_exact = decode_n(exact["params"])
    g_flex = decode_n(flex["params"])
    agree = (g_exact == g_flex).mean()
    print(f"greedy decode agreement exact vs flexible-8bit: {agree:.2%}")
    print(f"storage report: {mgr.storage_report()}")
