"""End-to-end driver: train a ~100M-parameter LM with the production train
step (microbatched grad accumulation, remat, sharded AdamW), checkpointing
into NeurStore every N steps (delta-compressed), with crash-restart.

Defaults are sized for this CPU container (--preset small ≈ 20M params,
a few minutes); --preset 100m is the full 100M config for real hardware.

    PYTHONPATH=src python examples/train_e2e.py --steps 60 --preset small
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.models.config import ModelConfig
from repro.optim import adamw_init

PRESETS = {
    "small": ModelConfig(
        name="e2e-20m", family="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab_size=8192, attn_chunk=128,
        param_dtype="float32", compute_dtype="float32"),
    "100m": ModelConfig(
        name="e2e-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab_size=32768, attn_chunk=256,
        param_dtype="float32", compute_dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/neurstore_e2e_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"model {cfg.name}: {cfg.n_params/1e6:.1f}M params")
    data = SyntheticLM(cfg.vocab_size, seed=0)
    mgr = CheckpointManager(args.ckpt_dir)

    start = 0
    if args.resume and mgr.latest_step() is not None:
        start, state = mgr.restore()
        params = jax.tree.map(jnp.asarray, state["params"])
        opt = jax.tree.map(jnp.asarray, state["opt"])
        print(f"resumed from step {start} (delta-compressed checkpoint)")
    else:
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)

    step_fn = jax.jit(make_train_step(cfg, args.microbatches, lr=3e-4))
    losses = []
    t0 = time.time()
    for step in range(start, start + args.steps):
        batch = data.batch(step, args.batch, args.seq)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 10 == 0:
            tput = args.batch * args.seq * (step - start + 1) / (time.time() - t0)
            print(f"step {step:4d} loss {loss:.4f} ({tput:,.0f} tok/s)")
        if (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, params, opt, blocking=False)
    mgr.wait()
    print(f"final loss {np.mean(losses[-5:]):.4f} "
          f"(start {np.mean(losses[:5]):.4f})")
    print(f"checkpoint storage: {mgr.storage_report()}")
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), "loss must decrease"


if __name__ == "__main__":
    main()
