"""The paper's e-commerce scenario: one pretrained model, many per-segment
fine-tunes. NeurStore dedups them against shared base tensors; compare
against PostgresML-blob and ELF*-file stores.

    PYTHONPATH=src python examples/finetune_dedup.py
"""

import sys
import tempfile

sys.path.insert(0, ".")
from benchmarks.workload import finetune, transformer_tensors  # noqa: E402

from repro.baselines import BlobStore, FileStore  # noqa: E402
from repro.core import StorageEngine  # noqa: E402

N_SEGMENTS = 6  # user segments, each with its own fine-tune

base = transformer_tensors(d=128, layers=4, seed=0)
models = [("pretrained", base)] + [
    (f"segment{i}", finetune(base, seed=10 + i, sigma=5e-4))
    for i in range(N_SEGMENTS)
]
orig = sum(sum(t.size * 4 for t in ts.values()) for _, ts in models)

with tempfile.TemporaryDirectory() as root:
    stores = {
        "neurstore": StorageEngine(root + "/ns"),
        "postgresml(blob)": BlobStore(root + "/pg"),
        "elf*(file)": FileStore(root + "/elf"),
    }
    print(f"{len(models)} models, {orig/1e6:.1f} MB raw")
    for name, store in stores.items():
        for mn, ts in models:
            store.save_model(mn, {"task": "ctr"}, ts)
        total = store.storage_bytes()["total"]
        print(f"  {name:18s} {total/1e6:7.1f} MB  ratio {orig/total:.2f}x")
    ns = stores["neurstore"]
    rep = ns.load_model("segment0").materialize()
    import numpy as np
    err = max(np.abs(rep[k] - dict(models)["segment0"][k]).max() for k in rep)
    # Bound: p (compression) + half-ulp of the float32 output cast.
    print(f"segment0 reconstruction max err: {err:.2e} "
          f"(p + f32 rounding = {2**-24 + 2**-24:.2e})")
    assert err <= 2 ** -23
